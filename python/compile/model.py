"""L2: LLaMA-style GQA transformer (RMSNorm + RoPE + SwiGLU), expressed as
AOT-lowerable entry points over a fixed-capacity, per-layer-length KV cache.

All entry points take the weights as a flat tuple in WEIGHT_NAMES order —
that order is the wire contract with rust/src/model/weights.rs (parameters
of the lowered HLO appear in exactly this order, followed by the non-weight
arguments in each entry point's documented order).

Cache layout — the KV cache is HOST-OWNED by the rust coordinator (the xla
crate returns executable outputs as one tuple that must round-trip through
host literals, so device residency buys nothing; rust owning the cache also
makes eviction a pure-rust gather). Per executable call the cache is
uploaded as:
    kv_k, kv_v : [L, B, Hkv, C, D] f32, rotary pre-applied to K
    lens       : [L, B] int32 — valid slots are the prefix 0..lens[l,b].
Per-layer lengths are what make Lethe's layerwise budgets expressible: after
a compaction, layer 3 may hold 96 tokens while layer 11 holds 384. C is a
*bucket*: the engine picks the smallest compiled C >= max live length, so a
pruned cache uploads and attends over less — the paper's latency win.

Entry points (static shapes; one HLO artifact per bucket):
    prefill(T)     — B=1 prompt ingest; returns last-token logits, the
                     prompt's K/V rows, and the RASR initial scores
                     (Eq. 2 summed over valid queries).
    decode(B, C)   — one token for B sequences; the new K/V is inserted
                     in-graph at slot lens[l,b] *for attention only* and
                     returned so rust can mirror the insert host-side;
                     returns logits + per-head attention scores.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.decode_attention import decode_attention
from compile.kernels.prefill_attention import prefill_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 46
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ff: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def group(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in weight_specs(self))


def weight_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) in wire order. Layer weights are stacked on axis 0 so
    the forward pass is a single lax.scan (fewer HLO params, XLA-fusable)."""
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    return [
        ("embed", (cfg.vocab_size, d)),
        ("ln1", (L, d)),
        ("wq", (L, d, hq * dh)),
        ("wk", (L, d, hkv * dh)),
        ("wv", (L, d, hkv * dh)),
        ("wo", (L, hq * dh, d)),
        ("ln2", (L, d)),
        ("w_gate", (L, d, f)),
        ("w_up", (L, d, f)),
        ("w_down", (L, f, d)),
        ("ln_f", (d,)),
        ("lm_head", (d, cfg.vocab_size)),
    ]


WEIGHT_NAMES = [n for n, _ in weight_specs(ModelConfig())]


def init_weights(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    ws = {}
    for name, shape in weight_specs(cfg):
        key, sub = jax.random.split(key)
        if name.startswith("ln"):
            ws[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) > 1 else shape[-1]
            ws[name] = (jax.random.normal(sub, shape, jnp.float32)
                        * (fan_in ** -0.5))
    return ws


def weights_tuple(ws: Dict[str, jax.Array]) -> Tuple[jax.Array, ...]:
    return tuple(ws[n] for n in WEIGHT_NAMES)


# --- building blocks -----------------------------------------------------

def rmsnorm(x, g, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope_tables(cfg: ModelConfig, positions):
    """positions [...]-> (cos, sin) each [..., D/2]."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., D]; cos/sin broadcastable [..., D/2]. Rotate-half pairing."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _split_heads(x, n_heads, d_head):
    return x.reshape(*x.shape[:-1], n_heads, d_head)


# --- decode entry point ---------------------------------------------------

def decode_step(cfg: ModelConfig, ws: Dict[str, jax.Array],
                kv_k, kv_v, lens, tokens, positions, *,
                interpret: bool = True):
    """One decode step for a batch group.

    kv_k, kv_v [L,B,Hkv,C,D]; lens [L,B] i32; tokens [B] i32;
    positions [B] i32 (absolute positions for RoPE).
    returns (logits [B,V], k_new [L,B,Hkv,D], v_new [L,B,Hkv,D],
             probs [L,B,Hq,C] f32 — column j scores cache slot j; the
             current token sits at slot lens[l,b])
    """
    B = tokens.shape[0]
    C = kv_k.shape[3]
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    x = ws["embed"][tokens]                                     # [B, d]
    cos, sin = rope_tables(cfg, positions)                      # [B, D/2]

    def layer(x, packed):
        (ln1, wq, wk, wv, wo, ln2, wg, wu, wd, k_l, v_l, len_l) = packed
        h = rmsnorm(x, ln1, cfg.norm_eps)
        q = apply_rope(_split_heads(h @ wq, hq, dh),
                       cos[:, None, :], sin[:, None, :])        # [B,Hq,D]
        k_new = apply_rope(_split_heads(h @ wk, hkv, dh),
                           cos[:, None, :], sin[:, None, :])    # [B,Hkv,D]
        v_new = _split_heads(h @ wv, hkv, dh)
        # In-graph insert at slot len_l[b]. vmapped dynamic_update_slice
        # touches one [Hkv, 1, D] row per sequence; the previous one-hot
        # formulation rewrote the entire [B, Hkv, C, D] cache (3 full
        # passes) and dominated the step at large C — see EXPERIMENTS.md
        # §Perf (L2).
        insert = jax.vmap(
            lambda cache, row, idx: jax.lax.dynamic_update_slice(
                cache, row[:, None, :], (0, idx, 0)))
        k_l = insert(k_l, k_new, len_l)
        v_l = insert(v_l, v_new, len_l)
        att, probs = decode_attention(q, k_l, v_l, len_l + 1,
                                      interpret=interpret)
        x = x + att.reshape(B, hq * dh) @ wo
        x = x + swiglu(rmsnorm(x, ln2, cfg.norm_eps), wg, wu, wd)
        return x, (k_new, v_new, probs)

    stacked = tuple(ws[n] for n in
                    ("ln1", "wq", "wk", "wv", "wo", "ln2",
                     "w_gate", "w_up", "w_down")) + (kv_k, kv_v, lens)
    x, (k_new, v_new, probs) = jax.lax.scan(layer, x, stacked)
    logits = rmsnorm(x, ws["ln_f"], cfg.norm_eps) @ ws["lm_head"]
    return logits, k_new, v_new, probs


# --- kernel-side dequantization (quantized decode entry points) -----------

# Mirror of rust/src/kvcache/quant.rs: group size along the head dim for
# the group-wise int4 codec, and the derived packed-row geometry.
Q4_GROUP = 32
NEG_INF = -1e30


def q4_groups(d_head: int) -> int:
    return -(-d_head // Q4_GROUP)


def q4_packed(d_head: int) -> int:
    return -(-d_head // 2)


def dequant_kv_q8(kv_q, kv_s):
    """Per-row symmetric int8 → f32: `x = code * scale`.

    kv_q [..., C, D] int8, kv_s [..., C] f32. The single f32 multiply is
    bit-identical to the host path (`quant::dequantize_span`), so the
    kernel-side-dequant decode step sees exactly the rows the f32 upload
    image would have carried.
    """
    return kv_q.astype(jnp.float32) * kv_s[..., None]


def dequant_kv_q4(kv_q, kv_s, kv_z, d_head: int):
    """Group-wise asymmetric int4 → f32: `x = code * scale + zero`.

    kv_q [..., C, ceil(D/2)] uint8 (two codes per byte, even element in
    the low nibble — the rust `quantize_row_q4_into` layout), kv_s / kv_z
    [..., C, G] f32 per-group scale / zero-point. Arithmetic is f32 (the
    host dequantizer accumulates in f64), so the result matches
    `quant::dequantize_row_q4` to f32 rounding — well inside
    `quant::dequant_error_bound`.
    """
    lo = jnp.bitwise_and(kv_q, 0x0F).astype(jnp.float32)
    hi = jnp.right_shift(kv_q, 4).astype(jnp.float32)
    codes = jnp.stack([lo, hi], axis=-1)
    codes = codes.reshape(*kv_q.shape[:-1], kv_q.shape[-1] * 2)[..., :d_head]
    scales = jnp.repeat(kv_s, Q4_GROUP, axis=-1)[..., :d_head]
    zeros = jnp.repeat(kv_z, Q4_GROUP, axis=-1)[..., :d_head]
    return codes * scales + zeros


def decode_step_q8(cfg: ModelConfig, ws: Dict[str, jax.Array],
                   k_q, k_s, v_q, v_s, lens, tokens, positions, *,
                   interpret: bool = True):
    """[`decode_step`] over q8-stored KV, dequantized in-graph.

    k_q/v_q [L,B,Hkv,C,D] int8; k_s/v_s [L,B,Hkv,C] f32; the rest as in
    `decode_step`. Uploading codes+scales instead of a dequantized f32
    image shrinks the per-step KV transfer ~4x (asymptotically in D).
    """
    return decode_step(cfg, ws, dequant_kv_q8(k_q, k_s),
                       dequant_kv_q8(v_q, v_s), lens, tokens, positions,
                       interpret=interpret)


def decode_step_q4(cfg: ModelConfig, ws: Dict[str, jax.Array],
                   k_q, k_s, k_z, v_q, v_s, v_z, lens, tokens, positions, *,
                   interpret: bool = True):
    """[`decode_step`] over group-wise q4-stored KV, dequantized in-graph.

    k_q/v_q [L,B,Hkv,C,ceil(D/2)] uint8; k_s/k_z/v_s/v_z [L,B,Hkv,C,G]
    f32; the rest as in `decode_step` (~8x smaller KV upload,
    asymptotically in D).
    """
    dh = cfg.d_head
    return decode_step(cfg, ws, dequant_kv_q4(k_q, k_s, k_z, dh),
                       dequant_kv_q4(v_q, v_s, v_z, dh), lens, tokens,
                       positions, interpret=interpret)


# --- prefill entry point ---------------------------------------------------

def prefill(cfg: ModelConfig, ws: Dict[str, jax.Array],
            tokens, length, *, interpret: bool = True):
    """Prompt ingest for ONE sequence (B=1), bucketed to T = tokens.shape[1].

    tokens [1,T] i32 (PAD beyond `length`); length [] i32.
    returns (logits [1,V] at the last real token,
             k_all, v_all [L,1,Hkv,T,D] (rows >= length are dead),
             scores [L,1,Hq,T] f32 — per-head attention mass per key,
             summed over the valid query rows: RASR init, Eq. 2)
    """
    B, T = tokens.shape
    assert B == 1
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    x = ws["embed"][tokens]                                     # [1,T,d]
    pos = jnp.arange(T, dtype=jnp.int32)
    cos, sin = rope_tables(cfg, pos)                            # [T,D/2]
    qrow_valid = (pos < length).astype(jnp.float32)             # [T]

    def layer(x, packed):
        (ln1, wq, wk, wv, wo, ln2, wg, wu, wd) = packed
        h = rmsnorm(x, ln1, cfg.norm_eps)
        q = _split_heads(h @ wq, hq, dh)                        # [1,T,Hq,D]
        k = _split_heads(h @ wk, hkv, dh)
        v = _split_heads(h @ wv, hkv, dh)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        qt = q.transpose(0, 2, 1, 3)                            # [1,Hq,T,D]
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        att, probs = prefill_attention(qt, kt, vt, interpret=interpret)
        # Collapse the query axis over *valid* rows only (pad rows attend
        # but must not pollute the RASR init): [1,Hq,T,T] -> [1,Hq,T].
        score = jnp.einsum("bhqk,q->bhk", probs, qrow_valid)
        x = x + att.transpose(0, 2, 1, 3).reshape(B, T, hq * dh) @ wo
        x = x + swiglu(rmsnorm(x, ln2, cfg.norm_eps), wg, wu, wd)
        return x, (kt, vt, score)

    stacked = tuple(ws[n] for n in
                    ("ln1", "wq", "wk", "wv", "wo", "ln2",
                     "w_gate", "w_up", "w_down"))
    x, (k_all, v_all, scores) = jax.lax.scan(layer, x, stacked)
    last = jnp.maximum(length - 1, 0)
    logits = rmsnorm(x[:, last, :], ws["ln_f"], cfg.norm_eps) @ ws["lm_head"]
    return logits, k_all, v_all, scores


# --- incremental prefill entry point --------------------------------------

# Static capacity of the prior-KV operand window: the largest prefill
# bucket, so any chunked prompt's consumed prefix fits. Must stay in sync
# with aot.PREFILL_TS (rust asserts it against meta["prefill_ts"]).
PREFILL_KV_CAP = 192


def prefill_kv(cfg: ModelConfig, ws: Dict[str, jax.Array],
               prior_k, prior_v, prior_len, tokens, length, *,
               interpret: bool = True):
    """One chunk of prompt ingest over an already-computed KV prefix.

    Chunked prefill used to re-run `prefill` over the whole growing prefix
    (O(consumed^2) per prompt); this entry point attends the T new tokens
    over the prior rows instead, so each token is computed exactly once.

    prior_k, prior_v [L,1,Hkv,P,D] f32 with P = PREFILL_KV_CAP (rows >=
    prior_len are dead); prior_len [] i32; tokens [1,T] i32 (PAD beyond
    `length`); length [] i32 — number of real tokens in this chunk.
    RoPE positions for the chunk are prior_len + arange(T), matching the
    absolute positions the prior rows were rotated at.
    returns (logits [1,V] at the last real chunk token,
             k_new, v_new [L,1,Hkv,T,D] — rows for this chunk only,
             scores [L,1,Hq,P+T] f32 — attention mass per key, prior keys
             first, summed over the valid chunk queries: the RASR
             *increment* this chunk contributes, Eq. 2)
    """
    B, T = tokens.shape
    assert B == 1
    P = prior_k.shape[3]
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    group = cfg.group
    x = ws["embed"][tokens]                                     # [1,T,d]
    tpos = jnp.arange(T, dtype=jnp.int32)
    cos, sin = rope_tables(cfg, prior_len + tpos)               # [T,D/2]
    qrow_valid = (tpos < length).astype(jnp.float32)            # [T]
    scale = 1.0 / (dh ** 0.5)

    # Key mask over the concatenated [prior | chunk] axis: a prior key j
    # is visible iff j < prior_len; a chunk key j is visible to chunk
    # query q iff j <= q (causal within the chunk) and j < length.
    jprior = jnp.arange(P, dtype=jnp.int32)
    prior_ok = jnp.broadcast_to((jprior < prior_len)[None, :], (T, P))
    new_ok = (tpos[None, :] <= tpos[:, None]) & (tpos[None, :] < length)
    mask = jnp.concatenate([prior_ok, new_ok], axis=1)          # [T,P+T]

    def layer(x, packed):
        (ln1, wq, wk, wv, wo, ln2, wg, wu, wd, pk, pv) = packed
        h = rmsnorm(x, ln1, cfg.norm_eps)
        q = apply_rope(_split_heads(h @ wq, hq, dh),
                       cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(_split_heads(h @ wk, hkv, dh),
                       cos[None, :, None, :], sin[None, :, None, :])
        v = _split_heads(h @ wv, hkv, dh)
        qt = q.transpose(0, 2, 1, 3)                            # [1,Hq,T,D]
        kt = k.transpose(0, 2, 1, 3)                            # [1,Hkv,T,D]
        vt = v.transpose(0, 2, 1, 3)
        kcat = jnp.repeat(jnp.concatenate([pk, kt], axis=2), group, axis=1)
        vcat = jnp.repeat(jnp.concatenate([pv, vt], axis=2), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kcat) * scale     # [1,Hq,T,P+T]
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.where(mask[None, None, :, :], jnp.exp(s - m), 0.0)
        probs = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
        att = jnp.einsum("bhqk,bhkd->bhqd", probs, vcat)
        score = jnp.einsum("bhqk,q->bhk", probs, qrow_valid)    # [1,Hq,P+T]
        x = x + att.transpose(0, 2, 1, 3).reshape(B, T, hq * dh) @ wo
        x = x + swiglu(rmsnorm(x, ln2, cfg.norm_eps), wg, wu, wd)
        return x, (kt, vt, score)

    stacked = tuple(ws[n] for n in
                    ("ln1", "wq", "wk", "wv", "wo", "ln2",
                     "w_gate", "w_up", "w_down")) + (prior_k, prior_v)
    x, (k_new, v_new, scores) = jax.lax.scan(layer, x, stacked)
    last = jnp.maximum(length - 1, 0)
    logits = rmsnorm(x[:, last, :], ws["ln_f"], cfg.norm_eps) @ ws["lm_head"]
    return logits, k_new, v_new, scores


# --- training-time forward (shares blocks with the serving path) ----------

def train_forward(cfg: ModelConfig, ws, tokens):
    """Teacher-forced logits [B,T,V] with the pure-jnp oracle attention
    (ref.py semantics == kernel semantics, pytest-enforced)."""
    from compile.kernels.ref import prefill_attention_ref

    B, T = tokens.shape
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    x = ws["embed"][tokens]
    pos = jnp.arange(T, dtype=jnp.int32)
    cos, sin = rope_tables(cfg, pos)

    def layer(x, packed):
        (ln1, wq, wk, wv, wo, ln2, wg, wu, wd) = packed
        h = rmsnorm(x, ln1, cfg.norm_eps)
        q = apply_rope(_split_heads(h @ wq, hq, dh),
                       cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(_split_heads(h @ wk, hkv, dh),
                       cos[None, :, None, :], sin[None, :, None, :])
        v = _split_heads(h @ wv, hkv, dh)
        att, _ = prefill_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), 1.0 / (dh ** 0.5))
        x = x + att.transpose(0, 2, 1, 3).reshape(B, T, hq * dh) @ wo
        x = x + swiglu(rmsnorm(x, ln2, cfg.norm_eps), wg, wu, wd)
        return x, ()

    stacked = tuple(ws[n] for n in
                    ("ln1", "wq", "wk", "wv", "wo", "ln2",
                     "w_gate", "w_up", "w_down"))
    x, _ = jax.lax.scan(layer, x, stacked)
    return rmsnorm(x, ws["ln_f"], cfg.norm_eps) @ ws["lm_head"]
