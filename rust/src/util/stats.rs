//! Summary statistics and the measurement core of the bench harness
//! (criterion substitute): warmup + timed iterations + robust summaries.

use std::time::{Duration, Instant};

/// Streaming mean/variance (Welford). Used by metrics counters.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Batch summary with percentiles (nearest-rank on a sorted copy).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty slice");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::default();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: s[0],
            max: *s.last().unwrap(),
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

/// Nearest-rank percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Fixed-boundary latency histogram (log-spaced buckets, microseconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    bounds_us: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1us .. ~100s, quarter-decade spacing.
        let bounds: Vec<f64> =
            (0..33).map(|i| 10f64.powf(i as f64 / 4.0)).collect();
        let n = bounds.len();
        LatencyHistogram { bounds_us: bounds, counts: vec![0; n + 1], total: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        let idx = self
            .bounds_us
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds_us.len());
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    *self.bounds_us.last().unwrap()
                };
            }
        }
        *self.bounds_us.last().unwrap()
    }
}

/// Criterion-substitute measurement: `warmup` untimed runs, then time
/// `iters` runs of `f`, returning per-iteration seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Render a bench row the way the harness prints everything:
/// name, mean, p50, p99 (milliseconds).
pub fn bench_row(name: &str, s: &Summary) -> String {
    format!(
        "{:<40} mean {:>9.3} ms   p50 {:>9.3} ms   p99 {:>9.3} ms   (n={})",
        name,
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3,
        s.n
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn bench_returns_reasonable_samples() {
        let s = bench(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 10);
        assert!(s.min >= 0.0 && s.mean < 1.0);
    }
}
