"""L1 Pallas kernel: masked GQA decode attention with score side-output.

This is the compute hot-spot of the serving decode step. One grid cell per
(batch, q-head); the kernel streams the C-capacity KV cache through VMEM in
`block_k` tiles (the HBM<->VMEM schedule that replaces the paper's CUDA
threadblock tiling — see DESIGN.md §Hardware-Adaptation), computing a
two-pass masked softmax:

  pass 1: blocked QK^T into a scores scratch row (C floats, VMEM-resident),
          tracking the running max for numerical stability;
  pass 2: blocked exp/normalise + PV accumulation, writing the attention
          probabilities out as a side output.

The probability side output IS the Lethe signal: the rust coordinator sums
it over heads (paper Eq. 2) to drive RASR (Eq. 5) and Algorithm 1. Emitting
it from inside the kernel while the tile is VMEM-resident means the score
path adds no extra HBM pass.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; structure (BlockSpec tiling, VMEM budget)
is still authored for TPU and audited in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, lens_ref, o_ref, p_ref, *,
                   block_k: int, scale: float):
    """Grid cell = (b, hq). Refs:
    q_ref [1, 1, D], k_ref/v_ref [1, C, D] (kv head = hq // group),
    lens_ref [1], o_ref [1, 1, D], p_ref [1, 1, C].
    """
    c = k_ref.shape[1]
    d = q_ref.shape[2]
    q = q_ref[0, 0, :].astype(jnp.float32)           # [D]
    n_valid = lens_ref[0]
    nblk = c // block_k

    # Pass 1: blocked scores + running max. The scores row lives in the
    # p_ref output block (VMEM) so no extra scratch is needed.
    def score_blk(i, running_max):
        ks = k_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = (ks @ q) * scale                          # [block_k]
        idx = i * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(idx < n_valid, s, NEG_INF)
        p_ref[0, 0, pl.dslice(i * block_k, block_k)] = s
        return jnp.maximum(running_max, jnp.max(s))

    # lens==0 rows leave m == NEG_INF; exp(s - m) is then exp(0) on masked
    # entries, which pass 2 re-masks to 0, so no special-casing is needed.
    m = jax.lax.fori_loop(0, nblk, score_blk, NEG_INF)

    # Pass 2: exp/normalise + PV accumulation per block.
    def pv_blk(i, carry):
        acc, denom = carry
        s = p_ref[0, 0, pl.dslice(i * block_k, block_k)]
        idx = i * block_k + jax.lax.iota(jnp.int32, block_k)
        e = jnp.where(idx < n_valid, jnp.exp(s - m), 0.0)
        p_ref[0, 0, pl.dslice(i * block_k, block_k)] = e
        vs = v_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        return acc + e @ vs, denom + jnp.sum(e)

    acc, denom = jax.lax.fori_loop(
        0, nblk, pv_blk, (jnp.zeros((d,), jnp.float32), 0.0))
    inv = 1.0 / jnp.maximum(denom, 1e-30)
    o_ref[0, 0, :] = (acc * inv).astype(o_ref.dtype)

    # Final rescale of the stored exp() row into probabilities.
    def norm_blk(i, _):
        sl = pl.dslice(i * block_k, block_k)
        p_ref[0, 0, sl] = (p_ref[0, 0, sl] * inv).astype(p_ref.dtype)
        return 0

    jax.lax.fori_loop(0, nblk, norm_blk, 0)


def decode_attention(q, k, v, lens, *, scale=None, block_k: int = 128,
                     interpret: bool = True):
    """Pallas masked GQA decode attention.

    q:    [B, Hq, D]; k, v: [B, Hkv, C, D]; lens: [B] int32.
    returns (out [B, Hq, D] same dtype as q, probs [B, Hq, C] f32)
    """
    b, hq, d = q.shape
    _, hkv, c, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_k = min(block_k, c)
    assert c % block_k == 0, (c, block_k)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               scale=float(scale))
    return pl.pallas_call(
        kernel,
        grid=(b, hq),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),        # q
            pl.BlockSpec((1, None, c, d),
                         lambda i, j: (i, j // group, 0, 0)),        # k
            pl.BlockSpec((1, None, c, d),
                         lambda i, j: (i, j // group, 0, 0)),        # v
            pl.BlockSpec((1,), lambda i, j: (i,)),                   # lens
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),         # out
            pl.BlockSpec((1, 1, c), lambda i, j: (i, j, 0)),         # probs
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, c), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lens)


def vmem_bytes(c: int, d: int, block_k: int = 128) -> int:
    """Static VMEM footprint estimate per grid cell (f32): q + one K tile +
    one V tile + the C-float score row + accumulator. Used by the §Perf
    audit in EXPERIMENTS.md."""
    block_k = min(block_k, c)
    return 4 * (d + 2 * block_k * d + c + d)
