//! Figure 1: layerwise attention-sparsity heatmaps over decoding steps
//! (Hoyer metric, Eq. 1) for three prompts — the empirical motivation
//! for layer- and time-adaptive allocation. Also regenerates Figure 3's
//! retained-token maps (which slots survive, per layer, over steps).
//!
//! Output: fig1_sparsity.csv (prompt,step,layer,hoyer) heatmap data and
//! fig3_retention.csv (prompt,layer,position,retained) bitmaps, plus an
//! ASCII rendering of the heatmap.

use lethe::attn::score::ProbsView;
use lethe::attn::sparsity::hoyer_sparsity;
use lethe::bench_support::{try_engine, write_csv};
use lethe::config::ServingConfig;
use lethe::engine::SeqState;
use lethe::policy::{make_policy, PolicyKind};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn main() -> anyhow::Result<()> {
    let mut cfg = ServingConfig::default();
    cfg.lethe.evict_threshold = 48;
    // τ calibrates to the score-distribution scale (Table 6 sweep): the
    // tiny model's RASR ratios are compressed vs a 28-layer 7B, so the
    // figure uses the aggressive end to make the pruning mechanism
    // visible, mirroring the paper's Figure 3 regime.
    cfg.lethe.sparse_ratio = 25.0;
    let Some((mut engine, tok)) = try_engine(cfg) else { return Ok(()) };
    engine.keep_probs = true;
    let layers = engine.dims().n_layers;

    let mut rng = Rng::new(0xF161);
    let mut heat_csv = Vec::new();
    let mut ret_csv = Vec::new();

    for (pi, (pairs, hops)) in [(16usize, 3usize), (24, 4), (8, 2)]
        .iter()
        .enumerate()
    {
        let task = make_task(&mut rng, *pairs, *hops);
        let prompt = tok.encode_prompt(&task.prompt)?;
        // Lethe for prompts 0-1 (retention maps show real pruning),
        // FullKV for prompt 2 (unpruned sparsity reference).
        let kind = if pi == 2 { PolicyKind::FullKv } else { PolicyKind::Lethe };
        let mut group = engine.new_group(1, kind);
        // eos = -1: force a long decode so the temporal axis is visible
        // (the paper's heatmaps span thousands of steps).
        let seq = SeqState::new(
            pi as u64,
            make_policy(kind, &engine.cfg, layers),
            layers,
            80,
            -1,
        );
        engine.prefill(&mut group, 0, seq, &prompt)?;

        // Per-step raw sparsity per layer (before EMA smoothing).
        let mut grid: Vec<Vec<f64>> = Vec::new();
        let mut buf = Vec::new();
        while group.active() > 0 {
            engine.step(&mut group)?;
            if let Some(p) = engine.last_probs.take() {
                let pv = ProbsView::new(&p);
                let mut row = Vec::with_capacity(layers);
                for l in 0..layers {
                    let live = group.cache.len(l, 0).max(1);
                    pv.head_sum_into(l, 0, live, &mut buf);
                    row.push(hoyer_sparsity(&buf));
                }
                grid.push(row);
            }
            group.reap();
        }
        for (step, row) in grid.iter().enumerate() {
            for (l, s) in row.iter().enumerate() {
                heat_csv.push(format!("{pi},{step},{l},{s:.4}"));
            }
        }

        // ASCII heatmap (steps downsampled to <= 40 columns).
        println!(
            "\n=== Fig 1({}) prompt {pi}: pairs={pairs} hops={hops} \
             policy={} ===",
            (b'a' + pi as u8) as char,
            kind.label()
        );
        let cols = grid.len().min(40).max(1);
        let stride = (grid.len().max(1) + cols - 1) / cols;
        for l in (0..layers).rev() {
            let mut line = format!("layer {l:2} ");
            for c in 0..cols {
                let idx = (c * stride).min(grid.len().saturating_sub(1));
                let v = grid.get(idx).map(|r| r[l]).unwrap_or(0.0);
                let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
                line.push(shades[((v * 9.0) as usize).min(9)]);
            }
            println!("{line}");
        }
        println!("         (time → over {} decode steps; darker = sparser)",
                 grid.len());

        // Figure 3: retained-position bitmaps per layer. Reaping recycles
        // cache rows, so rerun the first prompt and inspect the live
        // cache just before completion.
        if pi == 0 {
            let mut g2 = engine.new_group(1, kind);
            let s2 = SeqState::new(
                99,
                make_policy(kind, &engine.cfg, layers),
                layers,
                80,
                -1,
            );
            engine.prefill(&mut g2, 0, s2, &prompt)?;
            while g2.active() > 0 && !g2.seq(0).is_done() {
                engine.step(&mut g2)?;
            }
            let mp = g2.seq(0).abs_pos.saturating_sub(1);
            for l in 0..layers {
                for (pos, kept) in
                    g2.cache.retention_bitmap(l, 0, mp).iter().enumerate()
                {
                    ret_csv.push(format!("{pi},{l},{pos},{}", *kept as u8));
                }
            }
            println!("\n=== Fig 3 — retained positions (prompt 0, {}) ===",
                     kind.label());
            for l in 0..layers {
                let bm = g2.cache.retention_bitmap(l, 0, mp);
                let kept = bm.iter().filter(|&&b| b).count();
                let line: String = bm
                    .iter()
                    .map(|&b| if b { '█' } else { '·' })
                    .collect();
                println!("layer {l:2} [{kept:3}/{:3}] {line}", mp + 1);
            }
        }
    }

    write_csv("fig1_sparsity.csv", "prompt,step,layer,hoyer", &heat_csv)?;
    write_csv("fig3_retention.csv", "prompt,layer,position,retained",
              &ret_csv)?;
    Ok(())
}
