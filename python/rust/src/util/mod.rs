pub mod prng;
