//! Quantized KV row primitives — the paper's composition claim ("Lethe
//! can be layered on top of quantized caches for compounded memory
//! savings", Related Work §Quantization).
//!
//! Per-row symmetric int8: each cached (layer, slot, head) K/V row of D
//! floats is stored as i8[D] + one f32 scale (KIVI-style per-token
//! granularity, the variant that preserves outlier channels best at this
//! row shape). 4×(1 − 33/132) ≈ 3.9× memory reduction vs f32; the
//! accuracy cost is bounded by the quantization-error tests below and is
//! orthogonal to (multiplies with) Lethe's token-count reduction.
//!
//! This module owns the *row-level* pieces: [`KvFormat`] (config/CLI
//! selection + byte accounting), [`kv_row_bytes`], and the
//! [`quantize_row`]/[`dequantize_row`] pair. The cache-level storage
//! built on them is [`super::backend::QuantI8`], a first-class
//! [`super::backend::KvStore`] engine backend selected with
//! `kv.format = "q8"` — the former side-car `QuantCache` promoted onto
//! the real serving path.

use anyhow::{bail, Result};

/// KV storage format: selects the engine storage backend
/// ([`super::backend::KvBackend`]) and prices byte accounting (Table 2).
/// Every `live_bytes`-style metric routes through [`kv_row_bytes`] so
/// memory numbers stay honest across storage backends.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvFormat {
    /// 4 bytes per element (the serving default).
    #[default]
    F32,
    /// Per-row symmetric int8: 1 byte per element + one f32 scale per
    /// (head, tensor) row.
    QuantI8,
}

impl KvFormat {
    /// Parse the config/CLI name (`kv.format`: "f32" | "q8").
    pub fn parse(s: &str) -> Result<KvFormat> {
        match s {
            "f32" => Ok(KvFormat::F32),
            "q8" => Ok(KvFormat::QuantI8),
            other => bail!(
                "unknown kv format '{other}' (expected \"f32\" or \"q8\")"
            ),
        }
    }

    /// Config/CLI name, inverse of [`KvFormat::parse`].
    pub fn label(self) -> &'static str {
        match self {
            KvFormat::F32 => "f32",
            KvFormat::QuantI8 => "q8",
        }
    }
}

/// Bytes to store one cached token row — K *and* V, all `kv_heads` heads
/// of `d_head` elements — in the given format.
pub fn kv_row_bytes(kv_heads: usize, d_head: usize, fmt: KvFormat) -> usize {
    let per_head = match fmt {
        KvFormat::F32 => d_head * 4,
        KvFormat::QuantI8 => d_head + 4,
    };
    kv_heads * per_head * 2
}

/// One quantized row: i8 mantissas + a power-independent f32 scale.
/// Convenience carrier for tests/tools; the [`super::backend::QuantI8`]
/// backend stores mantissas and scales in flat arrays instead (no
/// per-row heap allocation on the decode hot path) via
/// [`quantize_row_into`] / [`dequantize_span`].
#[derive(Clone, Debug, Default)]
pub struct QuantRow {
    pub q: Vec<i8>,
    pub scale: f32,
}

/// Symmetric per-row int8 quantization into a preallocated mantissa
/// span; returns the scale. Non-finite-safe: NaN and ±Inf elements
/// carry no usable magnitude, so they are skipped explicitly when
/// computing `amax` and stored as exact zeros (consistent with the
/// engine's NaN-safe argmax) — otherwise a single Inf would drive
/// `scale` to Inf and dequantize the whole row to NaN (0 × Inf).
pub fn quantize_row_into(x: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(q.len(), x.len());
    let amax = x
        .iter()
        .filter(|v| v.is_finite())
        .fold(0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        q.fill(0);
        return 0.0;
    }
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    for (qe, &v) in q.iter_mut().zip(x) {
        *qe = if v.is_finite() {
            (v * inv).round().clamp(-127.0, 127.0) as i8
        } else {
            0
        };
    }
    scale
}

/// Allocating convenience wrapper over [`quantize_row_into`].
pub fn quantize_row(x: &[f32]) -> QuantRow {
    let mut q = vec![0i8; x.len()];
    let scale = quantize_row_into(x, &mut q);
    QuantRow { q, scale }
}

/// Dequantize a flat mantissa span with its scale (the inverse of
/// [`quantize_row_into`]).
pub fn dequantize_span(q: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), q.len());
    for (o, &qe) in out.iter_mut().zip(q) {
        *o = qe as f32 * scale;
    }
}

pub fn dequantize_row(r: &QuantRow, out: &mut [f32]) {
    dequantize_span(&r.q, r.scale, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::{check, vec_f32};

    #[test]
    fn kv_row_bytes_by_format() {
        // 2 heads * 4 elems * 4 bytes * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::F32), 64);
        // 2 heads * (4 elems + 4-byte scale) * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::QuantI8), 32);
    }

    #[test]
    fn format_parse_roundtrips_and_rejects() {
        assert_eq!(KvFormat::parse("f32").unwrap(), KvFormat::F32);
        assert_eq!(KvFormat::parse("q8").unwrap(), KvFormat::QuantI8);
        for fmt in [KvFormat::F32, KvFormat::QuantI8] {
            assert_eq!(KvFormat::parse(fmt.label()).unwrap(), fmt);
        }
        assert!(KvFormat::parse("fp8").is_err());
        assert!(KvFormat::parse("").is_err());
        assert_eq!(KvFormat::default(), KvFormat::F32);
    }

    #[test]
    fn roundtrip_error_is_bounded() {
        let mut rng = Rng::new(9);
        let x = vec_f32(&mut rng, 64, -3.0, 3.0);
        let q = quantize_row(&x);
        let mut y = vec![0f32; 64];
        dequantize_row(&q, &mut y);
        let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= amax / 127.0 * 0.5 + 1e-6,
                    "{a} vs {b}");
        }
    }

    #[test]
    fn zero_row_is_exact() {
        let q = quantize_row(&[0.0; 8]);
        assert_eq!(q.scale, 0.0);
        let mut y = [1f32; 8];
        dequantize_row(&q, &mut y);
        assert_eq!(y, [0.0; 8]);
    }

    #[test]
    fn quantize_row_skips_nans() {
        // NaNs must not poison the scale and must come back as exact 0.
        let x = [1.0, f32::NAN, -2.0, f32::NAN];
        let q = quantize_row(&x);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(q.q[1], 0);
        assert_eq!(q.q[3], 0);
        let mut y = [9f32; 4];
        dequantize_row(&q, &mut y);
        assert_eq!(y[1], 0.0);
        assert_eq!(y[3], 0.0);
        assert!((y[0] - 1.0).abs() <= 2.0 / 127.0 * 0.5 + 1e-6);
        assert!((y[2] + 2.0).abs() <= 2.0 / 127.0 * 0.5 + 1e-6);
    }

    #[test]
    fn quantize_row_skips_infinities() {
        // A single Inf must not drive the scale to Inf (which would
        // dequantize every element to 0 × Inf = NaN).
        let x = [f32::INFINITY, 3.0, f32::NEG_INFINITY, -1.5];
        let q = quantize_row(&x);
        assert!((q.scale - 3.0 / 127.0).abs() < 1e-9);
        assert_eq!(q.q[0], 0);
        assert_eq!(q.q[2], 0);
        let mut y = [0f32; 4];
        dequantize_row(&q, &mut y);
        assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
        assert_eq!(y[0], 0.0);
        assert!((y[1] - 3.0).abs() <= 3.0 / 127.0 * 0.5 + 1e-6);
        assert!((y[3] + 1.5).abs() <= 3.0 / 127.0 * 0.5 + 1e-6);
    }

    #[test]
    fn all_nan_row_quantizes_to_exact_zero() {
        let q = quantize_row(&[f32::NAN; 3]);
        assert_eq!(q.scale, 0.0);
        assert_eq!(q.q, vec![0; 3]);
        let mut y = [5f32; 3];
        dequantize_row(&q, &mut y);
        assert_eq!(y, [0.0; 3]);
    }

    #[test]
    fn quantize_into_matches_allocating_wrapper() {
        let mut rng = Rng::new(21);
        let x = vec_f32(&mut rng, 32, -4.0, 4.0);
        let r = quantize_row(&x);
        let mut q = vec![0i8; 32];
        let scale = quantize_row_into(&x, &mut q);
        assert_eq!(scale, r.scale);
        assert_eq!(q, r.q);
        let mut a = vec![0f32; 32];
        let mut b = vec![0f32; 32];
        dequantize_row(&r, &mut a);
        dequantize_span(&q, scale, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn property_quantization_relative_error() {
        check("quant-rel-err", 60, |rng, size| {
            let d = 4 + size;
            let x = vec_f32(rng, d, -10.0, 10.0);
            let q = quantize_row(&x);
            let mut y = vec![0f32; d];
            dequantize_row(&q, &mut y);
            let num: f32 =
                x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = x.iter().map(|a| a * a).sum::<f32>().max(1e-12);
            let rel = (num / den).sqrt();
            if rel > 0.02 {
                return Err(format!("relative L2 error {rel}"));
            }
            Ok(())
        });
    }

    #[test]
    fn compounded_savings_vs_f32() {
        // The Table 2 composition measured on a real q8-backed cache:
        // Lethe's ~91.6% token reduction × the q8 storage ratio ≈ 40x+
        // total. Goes through the live insert path so a backend that
        // silently stored f32-sized rows would fail the ratio.
        use super::super::{CacheDims, GroupCache};
        let dims = CacheDims {
            layers: 4,
            batch: 1,
            kv_heads: 2,
            capacity: 64,
            d_head: 32,
        };
        let mut c = GroupCache::with_format(dims, KvFormat::QuantI8);
        let row = vec![0.5f32; 64];
        for t in 0..50 {
            for l in 0..4 {
                c.insert(l, 0, &row, &row, t).unwrap();
            }
        }
        let ratio = c.f32_equivalent_bytes() as f64 / c.live_bytes() as f64;
        assert!(ratio > 3.4, "quant saving only {ratio:.2}x");
        assert_eq!(
            c.live_bytes(),
            4 * 50 * kv_row_bytes(2, 32, KvFormat::QuantI8)
        );
        let compounded = ratio * (1.0 / (1.0 - 0.916));
        assert!(compounded > 40.0);
    }
}
