//! Release-mode soak smoke: a churn workload of mixed-length prompts
//! over-subscribing the decode group under a tight KV byte budget and a
//! sparsity-directed `kv.mixed` format rule. Asserts the acceptance
//! criteria of the sequence-lifecycle serving core in one sustained
//! run with no idle window:
//!
//!   * over-subscription produces preempt/resume events and **zero**
//!     OOM-kills (`FinishReason::Oom` stays reserved for sequences
//!     that cannot fit even alone),
//!   * the `kv.mixed` map migrates layer formats **on a busy group** —
//!     `metrics.kv_layer_formats` changes while the same `GroupCache`
//!     (no rebuild) keeps serving,
//!   * decode steps keep landing during a long prompt's chunked
//!     prefill.
//!
//! Skipped (with a notice) when artifacts are not built; CI runs the
//! suite in release mode so this exercises the optimized scheduler.

use std::path::Path;

use lethe::bench_support::run_churn;
use lethe::config::{MixedKvRule, ServingConfig};
use lethe::engine::FinishReason;
use lethe::kvcache::KvFormat;
use lethe::policy::PolicyKind;
use lethe::util::prng::Rng;
use lethe::workload::make_task;

#[test]
fn churn_soak_preempts_resumes_and_migrates_without_oom() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 4;
    cfg.scheduler.prefill_chunk = 24;
    // Hysteresis long enough that the first co-residency preemption
    // (priced at the boot-time all-dense rates) lands before the mixed
    // map compresses the cache.
    cfg.scheduler.migrate_patience = 30;
    cfg.kv.mixed = Some(MixedKvRule {
        sparse: KvFormat::QuantI4,
        dense: KvFormat::F32,
        threshold: 0.1,
    });
    let rt = lethe::runtime::Runtime::load(dir).expect("runtime loads");
    let tok = lethe::model::Tokenizer::from_meta(&rt.meta).unwrap();
    let mut engine = lethe::engine::Engine::new(rt, cfg).unwrap();

    // Mixed-length churn: two long multi-hop prompts up front (the
    // pressure pair), then alternating short and long.
    let mut rng = Rng::new(7);
    let tasks: Vec<_> = (0..12)
        .map(|i| {
            if i < 2 || i % 2 == 1 {
                make_task(&mut rng, 12, 3)
            } else {
                make_task(&mut rng, 4, 1)
            }
        })
        .collect();
    // Budget: the first two prompts at boot-time (all-dense) rates plus
    // one decode row. Admission (which projects live + in-flight +
    // candidate bytes) legitimately accepts both, and their combined
    // decode growth crosses the budget within a few steps — forcing a
    // recompute-preemption instead of an OOM-kill.
    let lens: Vec<usize> = tasks
        .iter()
        .map(|t| tok.encode_prompt(&t.prompt).unwrap().len())
        .collect();
    let row = engine.rt.meta.kv_bytes_per_token();
    engine.cfg.scheduler.kv_budget_bytes = (lens[0] + lens[1] + 1) * row;

    let boot_formats = engine.metrics.kv_layer_formats.clone();
    let (stats, completions) =
        run_churn(&mut engine, &tok, PolicyKind::Lethe, &tasks, 16).unwrap();

    // Every request completes; none is OOM-killed.
    assert_eq!(completions.len(), tasks.len());
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..tasks.len() as u64).collect::<Vec<_>>());
    assert_eq!(stats.oom_finishes, 0, "preemption must replace OOM-kills");
    assert_eq!(engine.metrics.ooms, 0);

    // Over-subscription really happened, and pressure was handled by
    // preempt/resume.
    assert!(stats.peak_queue_depth >= 1, "group was never over-subscribed");
    assert!(stats.preemptions >= 1, "budget never forced a preemption");
    assert!(stats.resumes >= 1, "no preempted sequence resumed");
    assert_eq!(stats.resumes, stats.preemptions);

    // The mixed map migrated on the busy group: per-layer formats
    // changed without a group rebuild (run_churn keeps one Scheduler —
    // and thus one GroupCache — for the whole run), while the core was
    // under load.
    assert!(stats.kv_migrations >= 1, "kv.mixed never migrated a layer");
    assert!(
        stats.busy_migrations >= 1,
        "no migration landed while the core was serving load"
    );
    assert_ne!(
        engine.metrics.kv_layer_formats, boot_formats,
        "metrics never observed a changed per-layer format map"
    );
    assert!(
        engine
            .metrics
            .kv_layer_formats
            .iter()
            .any(|&f| f == KvFormat::QuantI4),
        "no layer ended up in the sparse format"
    );
    assert_eq!(engine.metrics.kv_migrations, stats.kv_migrations);

    // Chunked prefill interleaved with decode in the same ticks.
    assert!(
        stats.interleaved_ticks >= 1,
        "no decode step landed during a chunked prefill"
    );
}

/// Chaos soak: the same churn shape with seeded fault injection live at
/// every engine seam (KV-insert alloc, runtime execute, tick stalls)
/// and swap-to-host preemption forced on. Every request must still
/// reach exactly one typed completion — an injected failure finishes
/// its own sequence with `FinishReason::Error(..)` and frees the slot
/// instead of poisoning the tick or hanging the run.
///
/// The fault seed comes from `LETHE_FAULT_SEED` (CI runs a small seed
/// matrix in release mode), defaulting to 1; the same seed replays the
/// same fault schedule.
#[test]
fn chaos_soak_fault_injection_yields_typed_completions() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let seed: u64 = std::env::var("LETHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 4;
    cfg.scheduler.prefill_chunk = 24;
    // Make every preemption take the swap-to-host path (no per-token
    // cost can beat an unbeatable threshold), so serialization/restore
    // runs under injection too.
    cfg.scheduler.swap_threshold_bytes_per_token = usize::MAX;
    cfg.faults.seed = seed;
    cfg.faults.rate = 0.05;
    cfg.faults.stall_ms = 1;
    let rt = lethe::runtime::Runtime::load(dir).expect("runtime loads");
    let tok = lethe::model::Tokenizer::from_meta(&rt.meta).unwrap();
    let mut engine = lethe::engine::Engine::new(rt, cfg).unwrap();

    // Mixed-length churn: long multi-hop prompts interleaved with short
    // ones, over-subscribing the group.
    let mut rng = Rng::new(11);
    let tasks: Vec<_> = (0..12)
        .map(|i| {
            if i < 2 || i % 2 == 1 {
                make_task(&mut rng, 12, 3)
            } else {
                make_task(&mut rng, 4, 1)
            }
        })
        .collect();
    // Tight budget (pressure pair + one decode row) so preemption — and
    // with the threshold above, swap-out/restore — happens under fire.
    let lens: Vec<usize> = tasks
        .iter()
        .map(|t| tok.encode_prompt(&t.prompt).unwrap().len())
        .collect();
    let row = engine.rt.meta.kv_bytes_per_token();
    engine.cfg.scheduler.kv_budget_bytes = (lens[0] + lens[1] + 1) * row;

    let (stats, completions) =
        run_churn(&mut engine, &tok, PolicyKind::Lethe, &tasks, 16).unwrap();

    // No request is lost: every submitted id reaches exactly one
    // completion, failed or not.
    assert_eq!(completions.len(), tasks.len());
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..tasks.len() as u64).collect::<Vec<_>>());

    // The plan actually fired (rate 0.05 over hundreds of draws).
    assert!(
        engine.metrics.faults_injected > 0,
        "no fault was injected (seed {seed})"
    );

    // Failure accounting is exact: every Error finish is counted as a
    // sequence failure and nothing else is.
    let failed = completions
        .iter()
        .filter(|c| matches!(c.finish, FinishReason::Error(_)))
        .count() as u64;
    assert_eq!(
        failed, engine.metrics.seq_failures,
        "seq_failures must equal Error-finished completions (seed {seed})"
    );

    // Lifecycle invariants survive the chaos: every preemption swapped
    // (the threshold forces it), every swapped sequence came back, and
    // the bytes restored match the bytes swapped out.
    assert_eq!(stats.resumes, stats.preemptions);
    assert_eq!(engine.metrics.swap_preemptions, stats.preemptions);
    assert_eq!(engine.metrics.swap_bytes_in, engine.metrics.swap_bytes_out);

    // Injected faults surface as typed Error finishes, never as
    // OOM-kills or hangs.
    assert_eq!(stats.oom_finishes, 0, "faults must surface as Error, not Oom");
}
