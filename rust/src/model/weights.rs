//! Loads `artifacts/weights.bin` (raw little-endian f32, WEIGHT_NAMES
//! order) and uploads each tensor once as a persistent PJRT device buffer.
//! Weights never cross the host/device boundary again — every executable
//! call passes these buffers via `execute_b`.

use anyhow::{ensure, Context, Result};
use xla::{PjRtBuffer, PjRtClient};

use super::meta::ModelMeta;

pub struct Weights {
    /// Device buffers in manifest order (= lowered HLO parameter order).
    pub buffers: Vec<PjRtBuffer>,
    /// Host copies kept for inspection/tests (name, shape, data).
    pub host: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Weights {
    pub fn load(client: &PjRtClient, meta: &ModelMeta) -> Result<Weights> {
        let path = meta.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let total: usize = meta.weights.iter().map(|w| w.bytes).sum();
        ensure!(
            bytes.len() == total,
            "weights.bin is {} bytes, manifest says {total}",
            bytes.len()
        );
        let mut buffers = Vec::with_capacity(meta.weights.len());
        let mut host = Vec::with_capacity(meta.weights.len());
        for spec in &meta.weights {
            let raw = &bytes[spec.offset..spec.offset + spec.bytes];
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let n: usize = spec.shape.iter().product();
            ensure!(
                n == data.len(),
                "weight {}: shape {:?} != {} elements",
                spec.name,
                spec.shape,
                data.len()
            );
            let buf = client
                .buffer_from_host_buffer(&data, &spec.shape, None)
                .with_context(|| format!("uploading weight {}", spec.name))?;
            buffers.push(buf);
            host.push((spec.name.clone(), spec.shape.clone(), data));
        }
        Ok(Weights { buffers, host })
    }

    pub fn by_name(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.host
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
    }

    /// Total parameter count (sanity checks / reporting).
    pub fn param_count(&self) -> usize {
        self.host.iter().map(|(_, _, d)| d.len()).sum()
    }
}
