//! Substrate utilities built from scratch for the offline environment
//! (DESIGN.md §3): no tokio/clap/serde/criterion/proptest are available,
//! so the equivalents the serving stack needs live here.

pub mod argparse;
pub mod json;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod threadpool;
