fn main() {}
