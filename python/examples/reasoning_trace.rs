fn main() {}
