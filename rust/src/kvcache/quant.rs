//! Quantized KV row primitives — the paper's composition claim ("Lethe
//! can be layered on top of quantized caches for compounded memory
//! savings", Related Work §Quantization).
//!
//! Two quantized row codecs ship today:
//!
//!   * **Per-row symmetric int8** (`"q8"`): each cached (layer, slot,
//!     head) K/V row of D floats is stored as i8[D] + one f32 scale
//!     (KIVI-style per-token granularity, the variant that preserves
//!     outlier channels best at this row shape). 4×(1 − 33/132) ≈ 3.9×
//!     memory reduction vs f32 at D = 128.
//!   * **Group-wise asymmetric int4** (`"q4"`): the same row split into
//!     groups of [`Q4_GROUP`] = 32 elements along the head dim; each
//!     group stores an f32 scale + f32 zero-point and its elements as
//!     4-bit codes packed two nibbles per byte (even index = low nibble).
//!     ≈ 5.3× reduction vs f32 at D = 128; the group granularity bounds
//!     the error blast radius of a single outlier channel.
//!
//! The accuracy cost of both codecs is bounded by the quantization-error
//! tests below and is orthogonal to (multiplies with) Lethe's token-count
//! reduction.
//!
//! This module owns the *row-level* pieces: [`KvFormat`] (config/CLI
//! selection + byte accounting), [`kv_row_bytes`], the
//! [`quantize_row`]/[`dequantize_row`] int8 pair and the
//! [`quantize_row_q4_into`]/[`dequantize_row_q4`] int4 pair. The
//! cache-level storage built on them lives in [`super::backend`]
//! ([`super::backend::QuantI8`] / [`super::backend::QuantI4`]), selected
//! per layer via `kv.format` / `kv.layer_formats` / `kv.mixed`.
//!
//! These codecs are also the substrate of **live format migration**
//! ([`super::GroupCache::migrate_layer_format`]): a layer changing
//! format mid-serve is dequantized row-wise through the old codec and
//! re-encoded through the new one, so a migration's additional error is
//! bounded by one [`dequant_error_bound`] of the *destination* format
//! applied to the already-materialized f32 rows.

use anyhow::{bail, Result};

/// KV storage format: selects the engine storage backend
/// ([`super::backend::KvBackend`]) and prices byte accounting (Table 2).
/// Every `live_bytes`-style metric routes through [`kv_row_bytes`] so
/// memory numbers stay honest across storage backends.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvFormat {
    /// 4 bytes per element (the serving default).
    #[default]
    F32,
    /// Per-row symmetric int8: 1 byte per element + one f32 scale per
    /// (head, tensor) row.
    QuantI8,
    /// Group-wise asymmetric int4: half a byte per element + one f32
    /// scale and one f32 zero-point per [`Q4_GROUP`]-element group.
    QuantI4,
}

impl KvFormat {
    /// Parse the config/CLI name (`kv.format`: "f32" | "q8" | "q4").
    pub fn parse(s: &str) -> Result<KvFormat> {
        match s {
            "f32" => Ok(KvFormat::F32),
            "q8" => Ok(KvFormat::QuantI8),
            "q4" => Ok(KvFormat::QuantI4),
            other => bail!(
                "unknown kv format '{other}' \
                 (expected \"f32\", \"q8\" or \"q4\")"
            ),
        }
    }

    /// Config/CLI name, inverse of [`KvFormat::parse`].
    pub fn label(self) -> &'static str {
        match self {
            KvFormat::F32 => "f32",
            KvFormat::QuantI8 => "q8",
            KvFormat::QuantI4 => "q4",
        }
    }
}

/// Bytes to store one cached token row — K *and* V, all `kv_heads` heads
/// of `d_head` elements — in the given format.
pub fn kv_row_bytes(kv_heads: usize, d_head: usize, fmt: KvFormat) -> usize {
    let per_head = match fmt {
        KvFormat::F32 => d_head * 4,
        KvFormat::QuantI8 => d_head + 4,
        // Packed nibbles + (scale, zero) f32 pair per group.
        KvFormat::QuantI4 => {
            q4_packed_bytes(d_head) + q4_groups(d_head) * 8
        }
    };
    kv_heads * per_head * 2
}

/// One quantized row: i8 mantissas + a power-independent f32 scale.
/// Convenience carrier for tests/tools; the [`super::backend::QuantI8`]
/// backend stores mantissas and scales in flat arrays instead (no
/// per-row heap allocation on the decode hot path) via
/// [`quantize_row_into`] / [`dequantize_span`].
#[derive(Clone, Debug, Default)]
pub struct QuantRow {
    /// Signed int8 mantissas, one per row element.
    pub q: Vec<i8>,
    /// Dequantization scale: `x ≈ q * scale`.
    pub scale: f32,
}

/// Symmetric per-row int8 quantization into a preallocated mantissa
/// span; returns the scale. Non-finite-safe: NaN and ±Inf elements
/// carry no usable magnitude, so they are skipped explicitly when
/// computing `amax` and stored as exact zeros (consistent with the
/// engine's NaN-safe argmax) — otherwise a single Inf would drive
/// `scale` to Inf and dequantize the whole row to NaN (0 × Inf).
pub fn quantize_row_into(x: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(q.len(), x.len());
    let amax = x
        .iter()
        .filter(|v| v.is_finite())
        .fold(0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        q.fill(0);
        return 0.0;
    }
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    for (qe, &v) in q.iter_mut().zip(x) {
        *qe = if v.is_finite() {
            (v * inv).round().clamp(-127.0, 127.0) as i8
        } else {
            0
        };
    }
    scale
}

/// Allocating convenience wrapper over [`quantize_row_into`].
///
/// ```
/// use lethe::kvcache::quant::{dequantize_row, quantize_row};
/// let x = [0.5f32, -1.25, 2.0, 0.0];
/// let q = quantize_row(&x);
/// let mut y = [0.0f32; 4];
/// dequantize_row(&q, &mut y);
/// let tol = 2.0 / 127.0 * 0.5 + 1e-6; // amax / 127 / 2
/// for (a, b) in x.iter().zip(&y) {
///     assert!((a - b).abs() <= tol);
/// }
/// ```
pub fn quantize_row(x: &[f32]) -> QuantRow {
    let mut q = vec![0i8; x.len()];
    let scale = quantize_row_into(x, &mut q);
    QuantRow { q, scale }
}

/// Dequantize a flat mantissa span with its scale (the inverse of
/// [`quantize_row_into`]).
pub fn dequantize_span(q: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), q.len());
    for (o, &qe) in out.iter_mut().zip(q) {
        *o = qe as f32 * scale;
    }
}

/// Dequantize a [`QuantRow`] (inverse of [`quantize_row`]).
pub fn dequantize_row(r: &QuantRow, out: &mut [f32]) {
    dequantize_span(&r.q, r.scale, out);
}

/// Elements per int4 quantization group along the head dim (KIVI-style
/// group size). The last group of a row may be shorter when `d_head` is
/// not a multiple of this.
pub const Q4_GROUP: usize = 32;

/// Number of int4 groups needed to cover a `d_head`-element row.
pub const fn q4_groups(d_head: usize) -> usize {
    d_head.div_ceil(Q4_GROUP)
}

/// Bytes of packed int4 codes for a `d_head`-element row (two codes per
/// byte; odd tails leave the final high nibble zero).
pub const fn q4_packed_bytes(d_head: usize) -> usize {
    d_head.div_ceil(2)
}

/// Packed-upload geometry: bytes of quantized codes per (head, tensor)
/// row of `d_head` elements — the kernel-side-dequant operand layout
/// ([`super::PackedScratch`]). `None` for f32, which has no packed image.
pub fn packed_codes_per_row(d_head: usize, fmt: KvFormat) -> Option<usize> {
    match fmt {
        KvFormat::F32 => None,
        KvFormat::QuantI8 => Some(d_head),
        KvFormat::QuantI4 => Some(q4_packed_bytes(d_head)),
    }
}

/// Packed-upload geometry: f32 scale entries per (head, tensor) row
/// (q4 additionally carries the same count of zero-points).
pub fn packed_scales_per_row(d_head: usize, fmt: KvFormat) -> Option<usize> {
    match fmt {
        KvFormat::F32 => None,
        KvFormat::QuantI8 => Some(1),
        KvFormat::QuantI4 => Some(q4_groups(d_head)),
    }
}

/// Group-wise asymmetric int4 quantization of one row into preallocated
/// spans: `q` holds [`q4_packed_bytes`]`(x.len())` packed codes (element
/// `i` lives in byte `i / 2`; even `i` = low nibble), `scales`/`zeros`
/// hold one f32 each per [`q4_groups`]`(x.len())` group. An element
/// dequantizes to `code * scale + zero`.
///
/// Each group's range is `[min(gmin, 0), max(gmax, 0)]` over its finite
/// elements — widened to include 0.0 so that (a) non-finite elements
/// (NaN/±Inf carry no usable magnitude) can be stored as the code
/// nearest zero and (b) an all-zero or never-written group dequantizes
/// to exact zeros (scale = 0, zero = 0 — the `read_rows` determinism
/// obligation). The per-element error for finite inputs is bounded by
/// `scale / 2 = (hi − lo) / 30`.
///
/// ```
/// use lethe::kvcache::quant::{
///     dequantize_row_q4, q4_groups, q4_packed_bytes, quantize_row_q4_into,
/// };
/// // 40 elements → two groups (32 + 8) at group size 32.
/// let x: Vec<f32> = (0..40).map(|i| (i as f32) * 0.25 - 3.0).collect();
/// let mut q = vec![0u8; q4_packed_bytes(x.len())];
/// let mut scales = vec![0.0f32; q4_groups(x.len())];
/// let mut zeros = vec![0.0f32; q4_groups(x.len())];
/// quantize_row_q4_into(&x, &mut q, &mut scales, &mut zeros);
/// let mut y = vec![0.0f32; x.len()];
/// dequantize_row_q4(&q, &scales, &zeros, &mut y);
/// for (g, chunk) in x.chunks(32).enumerate() {
///     let lo = chunk.iter().fold(0f32, |m, &v| m.min(v));
///     let hi = chunk.iter().fold(0f32, |m, &v| m.max(v));
///     let tol = (hi - lo) / 15.0 * 0.5 + 1e-6;
///     for (a, b) in chunk.iter().zip(&y[g * 32..]) {
///         assert!((a - b).abs() <= tol, "{a} vs {b}");
///     }
/// }
/// ```
pub fn quantize_row_q4_into(
    x: &[f32],
    q: &mut [u8],
    scales: &mut [f32],
    zeros: &mut [f32],
) {
    debug_assert_eq!(q.len(), q4_packed_bytes(x.len()));
    debug_assert_eq!(scales.len(), q4_groups(x.len()));
    debug_assert_eq!(zeros.len(), q4_groups(x.len()));
    q.fill(0);
    for (g, chunk) in x.chunks(Q4_GROUP).enumerate() {
        // Finite-only range, widened to include 0.0 (see the doc above).
        let mut lo = 0f32;
        let mut hi = 0f32;
        for &v in chunk.iter().filter(|v| v.is_finite()) {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        // Range math in f64: a finite group spanning ±huge (e.g. ±3e38)
        // would overflow `hi - lo` to +Inf in f32, driving scale to Inf
        // and every dequantized element to NaN — the exact poisoning the
        // non-finite filtering above exists to prevent. The f64 width /
        // 15 always fits back into a finite f32.
        let scale = ((hi as f64 - lo as f64) / 15.0) as f32;
        zeros[g] = lo;
        scales[g] = scale;
        if scale == 0.0 {
            // Degenerate group (all zeros, or nothing finite): codes stay
            // 0 and the group dequantizes to exact `lo` (= 0.0) values.
            continue;
        }
        let inv = 1.0 / scale as f64;
        for (j, &v) in chunk.iter().enumerate() {
            let v = if v.is_finite() { v } else { 0.0 };
            let code = ((v as f64 - lo as f64) * inv)
                .round()
                .clamp(0.0, 15.0) as u8;
            let i = g * Q4_GROUP + j;
            q[i / 2] |= code << (4 * (i & 1));
        }
    }
}

/// Worst-case absolute dequantization error for a row whose exact
/// values are `exact`, stored in `fmt` — the single source of truth the
/// backend equivalence tests bound against (f32 is exact; q8 is the
/// per-row symmetric bound `amax / 127 / 2`; q4 is the per-group bound
/// `(hi − lo) / 15 / 2` over the zero-widened range, maximized across
/// groups). Non-finite elements are excluded, mirroring the quantizers.
pub fn dequant_error_bound(fmt: KvFormat, exact: &[f32]) -> f32 {
    match fmt {
        KvFormat::F32 => 0.0,
        KvFormat::QuantI8 => {
            let amax = exact
                .iter()
                .filter(|v| v.is_finite())
                .fold(0f32, |m, &v| m.max(v.abs()));
            amax / 127.0 * 0.5
        }
        KvFormat::QuantI4 => exact
            .chunks(Q4_GROUP)
            .map(|g| {
                let mut lo = 0f32;
                let mut hi = 0f32;
                for &v in g.iter().filter(|v| v.is_finite()) {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                // f64 width math, mirroring the quantizer: a finite
                // group spanning ±huge must yield a finite bound.
                ((hi as f64 - lo as f64) / 15.0 * 0.5) as f32
            })
            .fold(0f32, f32::max),
    }
}

/// Dequantize a packed group-wise int4 row (the inverse of
/// [`quantize_row_q4_into`]); `out.len()` is the row's element count.
pub fn dequantize_row_q4(
    q: &[u8],
    scales: &[f32],
    zeros: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), q4_packed_bytes(out.len()));
    debug_assert_eq!(scales.len(), q4_groups(out.len()));
    for (i, o) in out.iter_mut().enumerate() {
        let code = (q[i / 2] >> (4 * (i & 1))) & 0x0F;
        let g = i / Q4_GROUP;
        // f64 accumulation + clamp: `15 * scale` can exceed f32::MAX
        // mid-expression for extreme (still finite) groups even though
        // the final value `≈ hi` is representable; clamping keeps the
        // output finite for any finite stored (scale, zero).
        let x = code as f64 * scales[g] as f64 + zeros[g] as f64;
        *o = x.clamp(f32::MIN as f64, f32::MAX as f64) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::{check, vec_f32};

    #[test]
    fn kv_row_bytes_by_format() {
        // 2 heads * 4 elems * 4 bytes * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::F32), 64);
        // 2 heads * (4 elems + 4-byte scale) * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::QuantI8), 32);
        // 2 heads * (2 packed bytes + 1 group * 8) * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::QuantI4), 40);
        // At a realistic head dim the ordering is f32 > q8 > q4:
        // per head-tensor 128*4=512 vs 128+4=132 vs 64+4*8=96.
        assert_eq!(kv_row_bytes(1, 128, KvFormat::F32), 1024);
        assert_eq!(kv_row_bytes(1, 128, KvFormat::QuantI8), 264);
        assert_eq!(kv_row_bytes(1, 128, KvFormat::QuantI4), 192);
    }

    #[test]
    fn format_parse_roundtrips_and_rejects() {
        assert_eq!(KvFormat::parse("f32").unwrap(), KvFormat::F32);
        assert_eq!(KvFormat::parse("q8").unwrap(), KvFormat::QuantI8);
        assert_eq!(KvFormat::parse("q4").unwrap(), KvFormat::QuantI4);
        for fmt in [KvFormat::F32, KvFormat::QuantI8, KvFormat::QuantI4] {
            assert_eq!(KvFormat::parse(fmt.label()).unwrap(), fmt);
        }
        assert!(KvFormat::parse("fp8").is_err());
        assert!(KvFormat::parse("").is_err());
        assert_eq!(KvFormat::default(), KvFormat::F32);
    }

    fn q4_roundtrip(x: &[f32]) -> Vec<f32> {
        let mut q = vec![0u8; q4_packed_bytes(x.len())];
        let mut s = vec![0f32; q4_groups(x.len())];
        let mut z = vec![0f32; q4_groups(x.len())];
        quantize_row_q4_into(x, &mut q, &mut s, &mut z);
        let mut y = vec![0f32; x.len()];
        dequantize_row_q4(&q, &s, &z, &mut y);
        y
    }

    #[test]
    fn q4_geometry_helpers() {
        assert_eq!(q4_groups(32), 1);
        assert_eq!(q4_groups(33), 2);
        assert_eq!(q4_groups(64), 2);
        assert_eq!(q4_packed_bytes(4), 2);
        assert_eq!(q4_packed_bytes(5), 3);
    }

    #[test]
    fn packed_row_geometry_by_format() {
        assert_eq!(packed_codes_per_row(32, KvFormat::F32), None);
        assert_eq!(packed_scales_per_row(32, KvFormat::F32), None);
        assert_eq!(packed_codes_per_row(32, KvFormat::QuantI8), Some(32));
        assert_eq!(packed_scales_per_row(32, KvFormat::QuantI8), Some(1));
        assert_eq!(packed_codes_per_row(32, KvFormat::QuantI4), Some(16));
        assert_eq!(packed_scales_per_row(32, KvFormat::QuantI4), Some(1));
        assert_eq!(packed_codes_per_row(33, KvFormat::QuantI4), Some(17));
        assert_eq!(packed_scales_per_row(33, KvFormat::QuantI4), Some(2));
    }

    #[test]
    fn q4_roundtrip_error_is_group_bounded() {
        let mut rng = Rng::new(17);
        // 70 elements → 3 groups, one of them a short tail.
        let x = vec_f32(&mut rng, 70, -5.0, 5.0);
        let y = q4_roundtrip(&x);
        for (g, chunk) in x.chunks(Q4_GROUP).enumerate() {
            let lo = chunk.iter().fold(0f32, |m, &v| m.min(v));
            let hi = chunk.iter().fold(0f32, |m, &v| m.max(v));
            let tol = (hi - lo) / 15.0 * 0.5 + 1e-6;
            for (a, b) in chunk.iter().zip(&y[g * Q4_GROUP..]) {
                assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
            }
        }
    }

    #[test]
    fn q4_zero_row_is_exact_and_nonfinite_is_near_zero() {
        assert_eq!(q4_roundtrip(&[0.0; 40]), vec![0.0; 40]);
        // Non-finite elements must come back near zero and must not
        // poison the group's scale.
        let x = [1.0, f32::NAN, -2.0, f32::INFINITY, 0.5];
        let y = q4_roundtrip(&x);
        let tol = 3.0 / 15.0 * 0.5 + 1e-6; // range [-2, 1]
        assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
        assert!((y[0] - 1.0).abs() <= tol);
        assert!(y[1].abs() <= tol);
        assert!((y[2] + 2.0).abs() <= tol);
        assert!(y[3].abs() <= tol);
        assert!((y[4] - 0.5).abs() <= tol);
        // All-NaN rows degrade to exact zeros (scale 0, zero 0).
        assert_eq!(q4_roundtrip(&[f32::NAN; 3]), vec![0.0; 3]);
    }

    #[test]
    fn q4_one_sided_groups_still_represent_zero() {
        // All-positive group: the range is widened to [0, hi] so a
        // stored non-finite (code nearest 0) stays near zero.
        let x = [3.0f32, 4.0, 5.0, f32::NAN];
        let y = q4_roundtrip(&x);
        let tol = 5.0 / 15.0 * 0.5 + 1e-6;
        assert!((y[0] - 3.0).abs() <= tol);
        assert!(y[3].abs() <= tol);
    }

    #[test]
    fn q4_extreme_finite_group_stays_finite() {
        // A finite group spanning ±huge has a width that overflows f32:
        // the scale must not become Inf (which would dequantize the
        // whole group to NaN) and the round trip must stay finite and
        // within the (huge) group bound.
        let x = [3.0e38f32, -2.0e38, 0.0, 1.0];
        let y = q4_roundtrip(&x);
        assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
        let tol = dequant_error_bound(KvFormat::QuantI4, &x);
        assert!(tol.is_finite());
        // Tiny multiplicative slack: at e38 scale the bound itself is
        // subject to f32 rounding.
        let tol = tol * 1.001;
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn q4_odd_length_tail_nibble_roundtrips() {
        let x = [1.0f32, -1.0, 0.25];
        let y = q4_roundtrip(&x);
        let tol = 2.0 / 15.0 * 0.5 + 1e-6;
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn property_q4_relative_error() {
        check("q4-rel-err", 60, |rng, size| {
            let d = 4 + size;
            let x = vec_f32(rng, d, -10.0, 10.0);
            let y = q4_roundtrip(&x);
            let num: f32 =
                x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = x.iter().map(|a| a * a).sum::<f32>().max(1e-12);
            let rel = (num / den).sqrt();
            // 4-bit codes over a zero-including range: coarser than q8
            // (expected ≈ 6.7% relative L2 on uniform rows) but bounded.
            if rel > 0.12 {
                return Err(format!("relative L2 error {rel}"));
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_error_is_bounded() {
        let mut rng = Rng::new(9);
        let x = vec_f32(&mut rng, 64, -3.0, 3.0);
        let q = quantize_row(&x);
        let mut y = vec![0f32; 64];
        dequantize_row(&q, &mut y);
        let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= amax / 127.0 * 0.5 + 1e-6,
                    "{a} vs {b}");
        }
    }

    #[test]
    fn zero_row_is_exact() {
        let q = quantize_row(&[0.0; 8]);
        assert_eq!(q.scale, 0.0);
        let mut y = [1f32; 8];
        dequantize_row(&q, &mut y);
        assert_eq!(y, [0.0; 8]);
    }

    #[test]
    fn quantize_row_skips_nans() {
        // NaNs must not poison the scale and must come back as exact 0.
        let x = [1.0, f32::NAN, -2.0, f32::NAN];
        let q = quantize_row(&x);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(q.q[1], 0);
        assert_eq!(q.q[3], 0);
        let mut y = [9f32; 4];
        dequantize_row(&q, &mut y);
        assert_eq!(y[1], 0.0);
        assert_eq!(y[3], 0.0);
        assert!((y[0] - 1.0).abs() <= 2.0 / 127.0 * 0.5 + 1e-6);
        assert!((y[2] + 2.0).abs() <= 2.0 / 127.0 * 0.5 + 1e-6);
    }

    #[test]
    fn quantize_row_skips_infinities() {
        // A single Inf must not drive the scale to Inf (which would
        // dequantize every element to 0 × Inf = NaN).
        let x = [f32::INFINITY, 3.0, f32::NEG_INFINITY, -1.5];
        let q = quantize_row(&x);
        assert!((q.scale - 3.0 / 127.0).abs() < 1e-9);
        assert_eq!(q.q[0], 0);
        assert_eq!(q.q[2], 0);
        let mut y = [0f32; 4];
        dequantize_row(&q, &mut y);
        assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
        assert_eq!(y[0], 0.0);
        assert!((y[1] - 3.0).abs() <= 3.0 / 127.0 * 0.5 + 1e-6);
        assert!((y[3] + 1.5).abs() <= 3.0 / 127.0 * 0.5 + 1e-6);
    }

    #[test]
    fn all_nan_row_quantizes_to_exact_zero() {
        let q = quantize_row(&[f32::NAN; 3]);
        assert_eq!(q.scale, 0.0);
        assert_eq!(q.q, vec![0; 3]);
        let mut y = [5f32; 3];
        dequantize_row(&q, &mut y);
        assert_eq!(y, [0.0; 3]);
    }

    #[test]
    fn quantize_into_matches_allocating_wrapper() {
        let mut rng = Rng::new(21);
        let x = vec_f32(&mut rng, 32, -4.0, 4.0);
        let r = quantize_row(&x);
        let mut q = vec![0i8; 32];
        let scale = quantize_row_into(&x, &mut q);
        assert_eq!(scale, r.scale);
        assert_eq!(q, r.q);
        let mut a = vec![0f32; 32];
        let mut b = vec![0f32; 32];
        dequantize_row(&r, &mut a);
        dequantize_span(&q, scale, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn property_quantization_relative_error() {
        check("quant-rel-err", 60, |rng, size| {
            let d = 4 + size;
            let x = vec_f32(rng, d, -10.0, 10.0);
            let q = quantize_row(&x);
            let mut y = vec![0f32; d];
            dequantize_row(&q, &mut y);
            let num: f32 =
                x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = x.iter().map(|a| a * a).sum::<f32>().max(1e-12);
            let rel = (num / den).sqrt();
            if rel > 0.02 {
                return Err(format!("relative L2 error {rel}"));
            }
            Ok(())
        });
    }

    #[test]
    fn compounded_savings_vs_f32() {
        // The Table 2 composition measured on a real q8-backed cache:
        // Lethe's ~91.6% token reduction × the q8 storage ratio ≈ 40x+
        // total. Goes through the live insert path so a backend that
        // silently stored f32-sized rows would fail the ratio.
        use super::super::{CacheDims, GroupCache};
        let dims = CacheDims {
            layers: 4,
            batch: 1,
            kv_heads: 2,
            capacity: 64,
            d_head: 32,
        };
        let mut c = GroupCache::with_format(dims, KvFormat::QuantI8);
        let row = vec![0.5f32; 64];
        for t in 0..50 {
            for l in 0..4 {
                c.insert(l, 0, &row, &row, t).unwrap();
            }
        }
        let ratio = c.f32_equivalent_bytes() as f64 / c.live_bytes() as f64;
        assert!(ratio > 3.4, "quant saving only {ratio:.2}x");
        assert_eq!(
            c.live_bytes(),
            4 * 50 * kv_row_bytes(2, 32, KvFormat::QuantI8)
        );
        let compounded = ratio * (1.0 / (1.0 - 0.916));
        assert!(compounded > 40.0);
    }
}
