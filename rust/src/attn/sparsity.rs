//! Hoyer attention sparsity (paper Eq. 1):
//!
//!   Sparsity(a) = (sqrt(n) - ||a||_1 / ||a||_2) / (sqrt(n) - 1)
//!
//! in [0, 1]; 1 = one-hot (peaked/selective attention), 0 = uniform.
//! The per-layer EMA tracker drives Lethe's layerwise budget allocation:
//! dense layers (low sparsity) get larger eviction thresholds, sparse
//! layers can be pruned harder — replacing PyramidKV's fixed pyramid with
//! a runtime estimate (the paper's spatial adaptivity).

/// Hoyer sparsity of a non-negative score vector. Returns 0 for n <= 1 or
/// an all-zero vector (degenerate: no information).
pub fn hoyer_sparsity(a: &[f32]) -> f64 {
    let n = a.len();
    if n <= 1 {
        return 0.0;
    }
    let l1: f64 = a.iter().map(|&x| x.max(0.0) as f64).sum();
    let l2: f64 = a
        .iter()
        .map(|&x| {
            let x = x.max(0.0) as f64;
            x * x
        })
        .sum::<f64>()
        .sqrt();
    if l2 <= 0.0 {
        return 0.0;
    }
    let rn = (n as f64).sqrt();
    ((rn - l1 / l2) / (rn - 1.0)).clamp(0.0, 1.0)
}

/// Per-layer EMA of decode-step attention sparsity.
#[derive(Clone, Debug)]
pub struct SparsityTracker {
    ema: Vec<f64>,
    seen: Vec<bool>,
    alpha: f64,
}

impl SparsityTracker {
    pub fn new(n_layers: usize, alpha: f64) -> Self {
        SparsityTracker {
            ema: vec![0.0; n_layers],
            seen: vec![false; n_layers],
            alpha,
        }
    }

    /// Feed one step's head-summed attention vector for a layer.
    pub fn observe(&mut self, layer: usize, scores: &[f32]) {
        let s = hoyer_sparsity(scores);
        if !self.seen[layer] {
            self.ema[layer] = s;
            self.seen[layer] = true;
        } else {
            self.ema[layer] = self.alpha * s + (1.0 - self.alpha) * self.ema[layer];
        }
    }

    pub fn sparsity(&self, layer: usize) -> f64 {
        self.ema[layer]
    }

    pub fn all(&self) -> &[f64] {
        &self.ema
    }

    /// Budget multiplier for a layer: dense layers (sparsity -> 0) get up
    /// to 2x the base eviction threshold, fully sparse layers 1x. This is
    /// the spatial allocation rule (DESIGN.md §2).
    pub fn budget_scale(&self, layer: usize) -> f64 {
        if !self.seen[layer] {
            return 1.0;
        }
        2.0 - self.ema[layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_hot_is_max_sparsity() {
        let mut a = vec![0.0f32; 64];
        a[7] = 3.0;
        assert!((hoyer_sparsity(&a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_is_zero_sparsity() {
        let a = vec![0.25f32; 64];
        assert!(hoyer_sparsity(&a).abs() < 1e-9);
    }

    #[test]
    fn scale_invariant() {
        let a: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin().abs()).collect();
        let b: Vec<f32> = a.iter().map(|&x| 1000.0 * x).collect();
        assert!((hoyer_sparsity(&a) - hoyer_sparsity(&b)).abs() < 1e-6);
    }

    #[test]
    fn monotone_in_concentration() {
        // Mass concentrating on fewer entries => sparsity increases.
        let flat = vec![1.0f32; 16];
        let mut peaked = vec![0.1f32; 16];
        peaked[0] = 10.0;
        assert!(hoyer_sparsity(&peaked) > hoyer_sparsity(&flat));
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(hoyer_sparsity(&[]), 0.0);
        assert_eq!(hoyer_sparsity(&[1.0]), 0.0);
        assert_eq!(hoyer_sparsity(&[0.0; 8]), 0.0);
    }

    #[test]
    fn tracker_ema_and_budget_scale() {
        let mut t = SparsityTracker::new(2, 0.5);
        let mut onehot = vec![0.0f32; 32];
        onehot[0] = 1.0;
        t.observe(0, &onehot); // sparsity 1.0
        t.observe(1, &vec![1.0f32; 32]); // sparsity 0.0
        assert!(t.sparsity(0) > 0.99);
        assert!(t.sparsity(1) < 0.01);
        // Dense layer gets ~2x budget, sparse layer ~1x.
        assert!(t.budget_scale(1) > 1.9);
        assert!(t.budget_scale(0) < 1.1);
    }
}
