
#[cfg(test)]
mod probe {
    use super::*;

    #[test]
    #[ignore] // diagnostic probe, run with --ignored
    fn probe_20k_retention() {
        let mut cfg = crate::config::ServingConfig::default();
        cfg.baseline.budget = 768;
        cfg.lethe.evict_threshold = 512;
        cfg.lethe.sink_len = 16;
        let tc = TraceConfig {
            n_layers: 80, prompt_len: 512, gen_len: 20_000,
            ..TraceConfig::default()
        };
        let tr = run_trace(crate::policy::PolicyKind::Lethe, &cfg, &tc);
        println!("lethe: mean {:.0} final {:.0} events {}",
                 tr.mean_retained(), tr.final_retained(), tr.prune_events);
        for (i, r) in tr.retained.iter().enumerate() {
            if i % 4000 == 0 { println!("  t={i} retained={r:.0}"); }
        }
    }
}
