//! PJRT runtime: loads the AOT HLO-text artifacts, compiles them on the
//! CPU PJRT client, and exposes typed call wrappers. This is the only
//! module that touches the `xla` crate directly.

pub mod registry;
pub mod tensors;

pub use registry::{DecodeHandle, DecodeOut, PrefillOut, Runtime};
pub use tensors::{HostTensorF32, HostTensorI32};
