//! Miniature property-testing driver (proptest substitute). A property is
//! a closure over a seeded [`crate::util::prng::Rng`]; the driver runs N
//! cases, and on failure re-runs with "shrunk" size hints and reports the
//! failing seed so the case is reproducible with `check_seed`.

use crate::util::prng::Rng;

/// Run `prop` over `cases` random cases. `prop` returns Err(msg) to fail.
/// On failure, retries the same seed at smaller sizes to find a minimal
/// size that still fails, then panics with the seed + message.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let base = 0x4C45_5448_45u64; // "LETHE"
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64 * 0x9E37);
        let size = 2 + (case * 64 / cases.max(1));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: find the smallest size (same seed) that still fails.
            let mut min_size = size;
            let mut min_msg = msg;
            for s in (1..size).rev() {
                let mut r2 = Rng::new(seed);
                match prop(&mut r2, s) {
                    Err(m) => {
                        min_size = s;
                        min_msg = m;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={min_size}): \
                 {min_msg}"
            );
        }
    }
}

/// Re-run one exact case (debugging helper).
pub fn check_seed<F>(seed: u64, size: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng, size).expect("seeded property case failed");
}

/// Generator helpers.
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| lo + (hi - lo) * rng.f32()).collect()
}

pub fn vec_usize(rng: &mut Rng, len: usize, lo: usize, hi: usize) -> Vec<usize> {
    (0..len).map(|_| rng.range(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |rng, size| {
            let a = vec_f32(rng, size, -1.0, 1.0);
            let fwd: f32 = a.iter().sum();
            let rev: f32 = a.iter().rev().sum();
            if (fwd - rev).abs() < 1e-3 {
                Ok(())
            } else {
                Err(format!("{fwd} != {rev}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 5, |_rng, _size| Err("nope".into()));
    }
}
