//! PyramidKV (Cai et al. 2024): *static* layerwise budget allocation on a
//! pyramidal schedule — lower layers keep more tokens, upper layers fewer
//! — with H2O-style selection inside each layer's budget. The paper's
//! Figure 1 observation (non-monotone sparsity in reasoning models) is
//! exactly why this static pyramid loses to Lethe's runtime estimate on
//! CoT workloads.

use crate::config::BaselineParams;

use super::{top_k_indices, Capabilities, EvictionPolicy, LayerState};

pub struct PyramidKv {
    params: BaselineParams,
    /// Per-layer budgets, fixed at construction (the "static" in static
    /// allocation). Mean over layers equals `params.budget`.
    budgets: Vec<usize>,
}

impl PyramidKv {
    pub fn new(params: BaselineParams, n_layers: usize) -> Self {
        let beta = params.pyramid_beta.max(1.0);
        // Geometric decay from bottom to top, normalised to mean 1.
        let ws: Vec<f64> = (0..n_layers)
            .map(|l| beta.powf(-(l as f64) / (n_layers.max(2) - 1) as f64))
            .collect();
        let mean = ws.iter().sum::<f64>() / n_layers as f64;
        let budgets = ws
            .iter()
            .map(|w| ((w / mean) * params.budget as f64).round().max(4.0) as usize)
            .collect();
        PyramidKv { params, budgets }
    }

    pub fn budget(&self, layer: usize) -> usize {
        self.budgets[layer]
    }
}

impl EvictionPolicy for PyramidKv {
    fn name(&self) -> &'static str {
        "PyramidKV"
    }

    fn gamma(&self) -> f32 {
        1.0
    }

    fn plan(&mut self, layer: usize, st: &LayerState<'_>) -> Option<Vec<usize>> {
        let budget = self.budgets[layer];
        if st.len <= budget {
            return None;
        }
        let recent = (budget / 2).max(1);
        let heavy = budget - recent;
        let mut keep: Vec<usize> = (st.len - recent..st.len).collect();
        keep.extend(top_k_indices(&st.scores[..st.len - recent], heavy));
        keep.extend(0..self.params.sink_len.min(st.len));
        Some(keep)
    }

    /// Static budgets: `plan` is a pure no-op exactly while the live
    /// length stays within this layer's fixed budget.
    fn may_prune(&self, layer: usize, len: usize, _capacity: usize) -> bool {
        len > self.budgets[layer]
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            recency_aware: true,
            attention_aware: true,
            layerwise_budget: true,
            adaptive_budget: false,
            multi_step_pruning: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_decay_with_depth_and_mean_matches() {
        let params = BaselineParams { budget: 100, pyramid_beta: 3.0, ..Default::default() };
        let p = PyramidKv::new(params, 8);
        for l in 1..8 {
            assert!(p.budget(l) <= p.budget(l - 1),
                    "budget should not grow with depth");
        }
        let mean: f64 =
            (0..8).map(|l| p.budget(l) as f64).sum::<f64>() / 8.0;
        assert!((mean - 100.0).abs() < 10.0, "mean budget {mean}");
    }

    #[test]
    fn beta_one_is_uniform() {
        let params = BaselineParams { budget: 64, pyramid_beta: 1.0, ..Default::default() };
        let p = PyramidKv::new(params, 6);
        for l in 0..6 {
            assert_eq!(p.budget(l), 64);
        }
    }

    #[test]
    fn per_layer_trigger_points_differ() {
        let params = BaselineParams { budget: 32, pyramid_beta: 4.0, ..Default::default() };
        let mut p = PyramidKv::new(params, 4);
        let n = 40;
        let s = vec![0.1f32; n];
        let pos: Vec<i32> = (0..n as i32).collect();
        let st = LayerState {
            scores: &s,
            pos: &pos,
            len: n,
            step: 3,
            sparsity: 0.5,
            capacity: 512,
        };
        // Bottom layer budget > 40 => no prune; top layer budget < 40 =>
        // prune. The pyramid is visible through behaviour.
        assert!(p.budget(0) > n);
        assert!(p.plan(0, &st).is_none());
        assert!(p.budget(3) < n);
        assert!(p.plan(3, &st).is_some());
    }
}
